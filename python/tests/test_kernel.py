# pytest: Pallas kernels vs pure-jnp oracle — the CORE L1 correctness
# signal. Hypothesis sweeps shapes/gammas/block sizes; explicit cases pin
# the tile-edge paths (n < block, non-multiple shapes, single row).
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import center as center_k
from compile.kernels import rbf as rbf_k
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def _rand(rng, *shape):
    return jnp.asarray(rng.standard_normal(shape), dtype=jnp.float32)


# ---------------------------------------------------------------- rbf ---


class TestRbfGram:
    def test_matches_ref_square(self):
        rng = np.random.default_rng(0)
        x = _rand(rng, 50, 7)
        got = rbf_k.rbf_gram(x, x, 0.3)
        want = ref.rbf_gram_ref(x, x, 0.3)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)

    def test_matches_ref_rect(self):
        rng = np.random.default_rng(1)
        x = _rand(rng, 37, 12)
        y = _rand(rng, 91, 12)
        got = rbf_k.rbf_gram(x, y, 0.05)
        want = ref.rbf_gram_ref(x, y, 0.05)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)

    def test_diag_is_one(self):
        # K(x, x) = 1: the paper's normalization requirement (§3.1) holds
        # for RBF by construction.
        rng = np.random.default_rng(2)
        x = _rand(rng, 20, 4)
        k = rbf_k.rbf_gram(x, x, 1.7)
        np.testing.assert_allclose(np.diag(k), np.ones(20), atol=1e-5)

    def test_symmetric(self):
        rng = np.random.default_rng(3)
        x = _rand(rng, 33, 6)
        k = np.asarray(rbf_k.rbf_gram(x, x, 0.2))
        np.testing.assert_allclose(k, k.T, atol=1e-6)

    def test_values_in_unit_interval(self):
        rng = np.random.default_rng(4)
        x = _rand(rng, 25, 3)
        y = _rand(rng, 31, 3)
        k = np.asarray(rbf_k.rbf_gram(x, y, 0.9))
        assert (k >= 0).all() and (k <= 1 + 1e-6).all()

    def test_single_row(self):
        rng = np.random.default_rng(5)
        x = _rand(rng, 1, 8)
        y = _rand(rng, 5, 8)
        got = rbf_k.rbf_gram(x, y, 0.4)
        want = ref.rbf_gram_ref(x, y, 0.4)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)

    def test_exact_block_multiple(self):
        rng = np.random.default_rng(6)
        x = _rand(rng, 16, 5)
        y = _rand(rng, 32, 5)
        got = rbf_k.rbf_gram(x, y, 0.1, block=(16, 16))
        want = ref.rbf_gram_ref(x, y, 0.1)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)

    def test_block_bigger_than_input(self):
        rng = np.random.default_rng(7)
        x = _rand(rng, 3, 2)
        got = rbf_k.rbf_gram(x, x, 2.0, block=(128, 128))
        want = ref.rbf_gram_ref(x, x, 2.0)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)

    def test_f64_inputs_coerced(self):
        rng = np.random.default_rng(8)
        x = jnp.asarray(rng.standard_normal((9, 4)))  # f64 -> f32 inside
        k = rbf_k.rbf_gram(x, x, 0.5)
        assert k.dtype == jnp.float32

    def test_identical_points_give_one(self):
        x = jnp.ones((4, 3), dtype=jnp.float32)
        k = np.asarray(rbf_k.rbf_gram(x, x, 0.8))
        np.testing.assert_allclose(k, np.ones((4, 4)), rtol=1e-6)

    @settings(max_examples=25, deadline=None)
    @given(
        n=st.integers(1, 70),
        p=st.integers(1, 70),
        m=st.integers(1, 20),
        gamma=st.floats(1e-3, 5.0),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_shapes(self, n, p, m, gamma, seed):
        rng = np.random.default_rng(seed)
        x = _rand(rng, n, m)
        y = _rand(rng, p, m)
        got = rbf_k.rbf_gram(x, y, gamma, block=(32, 32))
        want = ref.rbf_gram_ref(x, y, gamma)
        np.testing.assert_allclose(got, want, rtol=2e-5, atol=1e-5)


# ------------------------------------------------------------- center ---


class TestCenterGram:
    def test_matches_ref_square(self):
        rng = np.random.default_rng(10)
        k = _rand(rng, 40, 40)
        got = center_k.center_gram(k)
        want = ref.center_gram_ref(k)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)

    def test_matches_ref_rect(self):
        rng = np.random.default_rng(11)
        k = _rand(rng, 23, 57)
        got = center_k.center_gram(k)
        want = ref.center_gram_ref(k)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)

    def test_row_and_col_sums_vanish(self):
        # Double-centering annihilates both marginals.
        rng = np.random.default_rng(12)
        k = _rand(rng, 30, 30)
        c = np.asarray(center_k.center_gram(k))
        np.testing.assert_allclose(c.sum(axis=0), 0.0, atol=1e-3)
        np.testing.assert_allclose(c.sum(axis=1), 0.0, atol=1e-3)

    def test_idempotent(self):
        rng = np.random.default_rng(13)
        k = _rand(rng, 25, 25)
        once = center_k.center_gram(k)
        twice = center_k.center_gram(once)
        np.testing.assert_allclose(once, twice, atol=1e-4)

    def test_centered_gram_is_gram_of_centered_features(self):
        # K_c = (phi - mu)^T (phi - mu) for the linear kernel.
        rng = np.random.default_rng(14)
        x = rng.standard_normal((20, 6)).astype(np.float32)
        k = jnp.asarray(x @ x.T)
        xc = x - x.mean(axis=0)
        want = xc @ xc.T
        got = center_k.center_gram(k)
        np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-4)

    def test_single_element(self):
        k = jnp.asarray([[3.5]], dtype=jnp.float32)
        got = np.asarray(center_k.center_gram(k))
        np.testing.assert_allclose(got, [[0.0]], atol=1e-6)

    @settings(max_examples=25, deadline=None)
    @given(
        n=st.integers(1, 80),
        p=st.integers(1, 80),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_shapes(self, n, p, seed):
        rng = np.random.default_rng(seed)
        k = _rand(rng, n, p)
        got = center_k.center_gram(k, block=(32, 32))
        want = ref.center_gram_ref(k)
        np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-4)


# ----------------------------------------------------------- composed ---


class TestComposedGram:
    def test_centered_rbf_pipeline(self):
        from compile import model

        rng = np.random.default_rng(20)
        x = _rand(rng, 45, 9)
        y = _rand(rng, 33, 9)
        got = model.gram_rbf_centered(x, y, 0.25)
        want = ref.center_gram_ref(ref.rbf_gram_ref(x, y, 0.25))
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)
