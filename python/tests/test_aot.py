# pytest: AOT lowering sanity — every artifact lowers to parseable HLO
# text (the Rust runtime's interchange format) and the manifest describes
# the shapes the Rust registry keys on. Uses the --small shape set so the
# suite stays fast; `make artifacts` lowers the full hot-shape set.
import json
import os

import jax
import pytest

from compile import aot

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module")
def small_set():
    return aot.build_artifact_set(
        feat_dim=8,
        gram_shapes=[(12, 12), (12, 24)],
        admm_shapes=[(12, 3)],
        z_dims=[24],
        power_dims=[16],
    )


class TestLowering:
    def test_all_artifacts_lower_to_hlo_text(self, small_set):
        for name, fn, arg_specs, meta in small_set:
            lowered = jax.jit(fn).lower(*arg_specs)
            text = aot.to_hlo_text(lowered)
            assert text.startswith("HloModule"), name
            assert "ROOT" in text, name

    def test_gram_artifact_contains_pallas_loop(self, small_set):
        # interpret=True lowers the Pallas kernel into plain HLO (while
        # loop over the grid) — verify the kernel actually lowered in.
        name, fn, arg_specs, _ = small_set[0]
        text = aot.to_hlo_text(jax.jit(fn).lower(*arg_specs))
        assert "exponential" in text  # the RBF exp survived lowering

    def test_manifest_shapes_match_specs(self, small_set):
        for name, fn, arg_specs, meta in small_set:
            assert len(meta["inputs"]) == len(arg_specs)
            for shape, spec in zip(meta["inputs"], arg_specs):
                assert tuple(shape) == tuple(spec.shape), name


class TestMainSmall:
    def test_writes_artifacts_and_manifest(self, tmp_path, monkeypatch):
        monkeypatch.setattr(
            "sys.argv",
            ["aot", "--outdir", str(tmp_path), "--feat-dim", "8", "--small"],
        )
        aot.main()
        manifest = json.loads((tmp_path / "manifest.json").read_text())
        assert manifest["feat_dim"] == 8
        assert len(manifest["artifacts"]) > 0
        for art in manifest["artifacts"]:
            path = tmp_path / art["file"]
            assert path.exists(), art["name"]
            head = path.read_text()[:200]
            assert head.startswith("HloModule")
