# pytest: full-algorithm validation of Alg. 1 on the NumPy reference —
# the paper's §6 claims hold on small instances:
#   * similarity to the central solution approaches 1 (Fig. 3 shape)
#   * DKPCA improves over local-only kPCA, most at small N_j (Fig. 4)
#   * more neighbors help (Fig. 5)
#   * augmented Lagrangian decreases for rho large enough (Theorem 2)
import numpy as np
import pytest

from tests.ref_dkpca import (
    RefDKPCA,
    central_kpca,
    center_gram,
    rbf_gram,
    similarity,
    top_eigvec,
)

GAMMA = 0.1


def make_blobs(rng, j, n, m=5, spread=0.7, n_classes=2, skew=0.0):
    """Per-node datasets from a shared class mixture; `skew` biases each
    node toward one class (data heterogeneity, §3.2)."""
    centers = rng.standard_normal((n_classes, m)) * 2.0
    xs = []
    for node in range(j):
        probs = np.full(n_classes, 1.0 / n_classes)
        if skew > 0:
            probs = probs * (1 - skew)
            probs[node % n_classes] += skew
        lab = rng.choice(n_classes, size=n, p=probs / probs.sum())
        xs.append(centers[lab] + rng.standard_normal((n, m)) * spread)
    return xs


def ring(j, k=1):
    """Ring topology: k neighbors on each side (|Omega| = 2k), §6.2."""
    return [
        sorted({(i + o) % j for o in range(-k, k + 1) if o != 0})
        for i in range(j)
    ]


def mean_similarity(xs, alphas, gamma=GAMMA):
    alpha_gt, _, kg, xg = central_kpca(xs, gamma)
    sims = []
    for j, x in enumerate(xs):
        kj = center_gram(rbf_gram(x, x, gamma))
        kx = center_gram(rbf_gram(x, xg, gamma))
        sims.append(similarity(alphas[j], kx, kj, alpha_gt, kg))
    return float(np.mean(sims))


def local_solutions(xs, gamma=GAMMA):
    out = []
    for x in xs:
        v, _ = top_eigvec(center_gram(rbf_gram(x, x, gamma)))
        out.append(v)
    return out


def run_dkpca(xs, adj, iters=30, seed=1, **kw):
    algo = RefDKPCA(xs, adj, GAMMA, seed=seed, **kw)
    algo.run(iters, rho2_schedule=[(0, 10.0), (10, 50.0), (20, 100.0)])
    return algo


class TestConvergesToCentral:
    def test_high_similarity_on_blobs(self):
        rng = np.random.default_rng(42)
        xs = make_blobs(rng, j=8, n=30)
        algo = run_dkpca(xs, ring(8))
        assert mean_similarity(xs, algo.alpha) > 0.97

    def test_beats_local_under_heterogeneity(self):
        rng = np.random.default_rng(7)
        xs = make_blobs(rng, j=6, n=15, skew=0.6)
        local = mean_similarity(xs, local_solutions(xs))
        algo = run_dkpca(xs, ring(6))
        dec = mean_similarity(xs, algo.alpha)
        assert dec > local

    def test_without_self_constraint_still_converges(self):
        # Alg. 1 exactly as printed (C_j = Omega_j, uniform rho).
        rng = np.random.default_rng(3)
        xs = make_blobs(rng, j=6, n=20)
        algo = RefDKPCA(xs, ring(6), GAMMA, include_self=False, rho2=50.0, seed=2)
        algo.run(40)
        assert mean_similarity(xs, algo.alpha) > 0.9


class TestFig4Shape:
    def test_improvement_shrinks_with_local_samples(self):
        rng = np.random.default_rng(11)
        gains = []
        for n in (10, 60):
            xs = make_blobs(rng, j=6, n=n, skew=0.5)
            local = mean_similarity(xs, local_solutions(xs))
            algo = run_dkpca(xs, ring(6))
            gains.append(mean_similarity(xs, algo.alpha) - local)
        assert gains[0] > gains[1] - 0.02  # small-N gain >= large-N gain


class TestFig5Shape:
    def test_more_neighbors_not_worse(self):
        rng = np.random.default_rng(13)
        xs = make_blobs(rng, j=8, n=20, skew=0.4)
        s1 = mean_similarity(xs, run_dkpca(xs, ring(8, k=1)).alpha)
        s2 = mean_similarity(xs, run_dkpca(xs, ring(8, k=2)).alpha)
        assert s2 > s1 - 0.05


class TestTheorem2:
    def test_lagrangian_converges_for_large_rho(self):
        # Theorem 2: for rho >= the Assumption-2 bound the augmented
        # Lagrangian decreases and converges. Empirically the decrease is
        # monotone up to a <1%-of-range ripple (the paper's Lemma-4 E2
        # bound is loose); we assert the convergent-decrease form.
        rng = np.random.default_rng(17)
        xs = make_blobs(rng, j=5, n=12)
        algo = RefDKPCA(xs, ring(5), GAMMA, rho1=500.0, rho2=500.0, seed=4)
        # rho clears the Assumption-2 bound on this instance.
        for j in range(5):
            lam = np.linalg.eigvalsh(algo.kc[j])
            lam1, s3 = lam[-1], float(np.sum(np.abs(lam) ** 3))
            omega = len(algo.adj[j])
            bound = (np.sqrt(lam1**4 + 8 * omega * lam1 * s3) + lam1**2) / (
                omega * lam1
            )
            assert 500.0 >= bound
        vals = []
        for _ in range(25):
            algo.step()
            vals.append(algo.lagrangian())
        diffs = np.diff(vals)
        total_drop = vals[0] - vals[-1]
        assert total_drop > 0
        # Past the 2-step zero-init transient, any single increase is a
        # tiny fraction of the total decrease.
        assert diffs[2:].max() < 0.01 * total_drop
        # The tail has stabilised (convergence of L).
        assert np.abs(diffs[-3:]).max() < 0.01 * total_drop


class TestCommunicationAccounting:
    def test_comm_cost_linear_in_neighbors_and_n(self):
        # §4.2: O(|Omega_j| N) floats per node per iteration.
        rng = np.random.default_rng(19)
        xs = make_blobs(rng, j=6, n=20)
        algo = RefDKPCA(xs, ring(6), GAMMA, seed=5)
        algo.step()
        per_iter = algo.comm_floats
        algo.step()
        assert algo.comm_floats == 2 * per_iter  # constant per iteration
        # Every node: |Omega|=2 neighbors, N=20: round A = 2*(20+20) in,
        # z scatter = 2*20 out; total per node 120, J=6 -> 720.
        assert per_iter == 6 * (2 * (20 + 20) + 2 * 20)


class TestDegenerateNode:
    def _degenerate_instance(self):
        rng = np.random.default_rng(23)
        xs = make_blobs(rng, j=5, n=15)
        direction = rng.standard_normal(5)
        t = rng.standard_normal((15, 1))
        xs[0] = t @ direction[None, :]  # rank-1 data at node 0
        return xs

    def _sims(self, xs, alphas):
        alpha_gt, _, kg, xg = central_kpca(xs, GAMMA)
        out = []
        for j, x in enumerate(xs):
            kj = center_gram(rbf_gram(x, x, GAMMA))
            kx = center_gram(rbf_gram(x, xg, GAMMA))
            out.append(similarity(alphas[j], kx, kj, alpha_gt, kg))
        return np.array(out)

    def test_sphere_mode_robust_to_rank_deficient_node(self):
        # Fig. 1(c): one node's data lie on a line. With the sphere
        # z-normalisation (the pre-relaxation ||z|| = 1 of (7)) healthy
        # nodes keep a high-quality solution.
        xs = self._degenerate_instance()
        algo = RefDKPCA(xs, ring(5), GAMMA, z_norm="sphere", seed=1)
        algo.run(60, rho2_schedule=[(0, 10.0), (10, 50.0), (20, 100.0)])
        sims = self._sims(xs, algo.alpha)
        assert np.isfinite(sims).all()
        assert float(np.mean(sims[1:])) > 0.9

    def test_ball_mode_collapses_documenting_ablation(self):
        # The relaxed ball constraint (11) admits the trivial fixed point
        # (alpha, z) = 0; a rank-deficient node drags the iteration into
        # it. This pins the FIG1C ablation behaviour (see DESIGN.md).
        xs = self._degenerate_instance()
        algo = RefDKPCA(xs, ring(5), GAMMA, z_norm="ball", seed=1)
        algo.run(60, rho2_schedule=[(0, 10.0), (10, 50.0), (20, 100.0)])
        sims = self._sims(xs, algo.alpha)
        assert np.isfinite(sims).all()
        assert float(np.mean(sims[1:])) < 0.9  # collapse (ball) ...
        obj = sum(
            float(np.linalg.norm(algo.kc[j] @ algo.alpha[j]) ** 2)
            for j in range(5)
        )
        assert obj < 1e-2  # ... towards the trivial solution
