# Full-algorithm NumPy reference of paper Alg. 1 (DKPCA-ADMM).
#
# This is the executable spec for the Rust implementation
# (rust/src/admm/): the kernelized update equations here are exactly the
# ones rust implements, and python/tests/test_dkpca_ref.py validates the
# paper's claims on it (similarity improves over local-only kPCA,
# augmented Lagrangian monotone decrease for rho large enough).
#
# Generalisation used throughout (matching §6.1's tuning): each node j
# holds one constraint per element of C_j = {j} + Omega_j (the
# self-constraint, penalty rho1) or C_j = Omega_j (include_self=False,
# the algorithm exactly as printed), with per-constraint penalty
# rho_{j,k}. With uniform rho and C_j = Omega_j this reduces verbatim to
# (10)-(13).
import numpy as np


def rbf_gram(x, y, gamma):
    """exp(-gamma ||x_i - y_j||^2); x (n, m), y (p, m)."""
    d2 = (
        np.sum(x * x, axis=1)[:, None]
        + np.sum(y * y, axis=1)[None, :]
        - 2.0 * x @ y.T
    )
    return np.exp(-gamma * np.maximum(d2, 0.0))


def center_gram(k):
    """Paper §6.1 double-centering of a (cross-)Gram block."""
    rm = k.mean(axis=1, keepdims=True)
    cm = k.mean(axis=0, keepdims=True)
    gm = k.mean()
    return k - rm - cm + gm


def top_eigvec(k):
    """Unit top eigenvector of a symmetric matrix."""
    w, v = np.linalg.eigh(k)
    return v[:, -1], w[-1]


def central_kpca(xs, gamma):
    """Ground truth alpha_gt: top eigenvector of the centered global Gram."""
    x = np.concatenate(xs, axis=0)
    k = center_gram(rbf_gram(x, x, gamma))
    v, lam = top_eigvec(k)
    return v, lam, k, x


def similarity(alpha_j, k_cross_c, kj_c, alpha_gt, k_global_c):
    """Paper §6.1 similarity metric (|.| — eigvector sign is arbitrary)."""
    num = abs(alpha_j @ k_cross_c @ alpha_gt)
    den = np.sqrt(
        abs(alpha_j @ kj_c @ alpha_j) * abs(alpha_gt @ k_global_c @ alpha_gt)
    )
    return num / max(den, 1e-30)


class RefDKPCA:
    """Decentralized kernel PCA with projection consensus constraints.

    xs: list of J local datasets (N_j, M); adj: list of J neighbor lists
    (symmetric, connected). Nodes exchange raw data with neighbors at
    setup (per the paper; optionally noised by the caller beforehand).
    """

    def __init__(
        self,
        xs,
        adj,
        gamma,
        rho1=100.0,
        rho2=10.0,
        jitter=1e-5,
        include_self=True,
        z_norm="ball",
        seed=0,
    ):
        # z_norm: "ball" follows eq. (11) exactly (project onto ||z|| <= 1
        # only when outside); "sphere" always renormalises to ||z|| = 1 —
        # the pre-relaxation constraint of (7). Ball admits the trivial
        # (alpha, z) = 0 fixed point, which rank-deficient nodes can drag
        # the relaxed iteration into (Fig. 1(c) ablation); sphere is robust
        # to that at the cost of slower early convergence.
        self.xs = [np.asarray(x, dtype=np.float64) for x in xs]
        self.adj = [list(a) for a in adj]
        self.gamma = gamma
        self.rho1 = rho1
        self.rho2 = rho2
        self.include_self = include_self
        self.z_norm = z_norm
        self.J = len(xs)
        rng = np.random.default_rng(seed)

        # Constraint set C_j: columns of B/P for node j, in this order.
        self.cset = [
            ([j] + self.adj[j]) if include_self else list(self.adj[j])
            for j in range(self.J)
        ]
        # Contributors to z_k == C_k by graph symmetry.
        self.kc = []     # centered local Gram (no jitter)
        self.kinv = []   # inverse of jittered centered local Gram
        for j in range(self.J):
            kc = center_gram(rbf_gram(self.xs[j], self.xs[j], gamma))
            self.kc.append(kc)
            self.kinv.append(
                np.linalg.inv(kc + jitter * len(self.xs[j]) * np.eye(len(kc)))
            )
        # Centered cross-Gram blocks among each z-group (what node k can
        # compute from the raw data of C_k).
        self.gz = []
        for k in range(self.J):
            grp = self.cset[k]
            blocks = [
                [
                    center_gram(rbf_gram(self.xs[a], self.xs[b], gamma))
                    for b in grp
                ]
                for a in grp
            ]
            self.gz.append(np.block(blocks))

        self.alpha = [rng.standard_normal(len(x)) for x in self.xs]
        self.alpha = [a / np.linalg.norm(a) for a in self.alpha]
        self.b = [
            np.zeros((len(self.xs[j]), len(self.cset[j]))) for j in range(self.J)
        ]
        # P columns: phi(X_j)^T z_k for k in C_j; start at zero.
        self.p = [np.zeros_like(b) for b in self.b]
        self.comm_floats = 0  # §4.2 communication accounting

    def rho_vec(self, j):
        """Per-constraint penalties for node j's columns (C_j order)."""
        return np.array(
            [
                self.rho1 if (self.include_self and k == j) else self.rho2
                for k in self.cset[j]
            ]
        )

    def s_total(self, k):
        """sum_{l in contributors(k)} rho_{l,k} (the z-averaging weight)."""
        tot = 0.0
        for l in self.cset[k]:
            tot += self.rho1 if (self.include_self and l == k) else self.rho2
        return tot

    def z_update(self):
        """Eqs. (10)/(11), kernelized: returns per-node received P."""
        p_new = [np.zeros_like(b) for b in self.b]
        for k in range(self.J):
            grp = self.cset[k]
            s_k = self.s_total(k)
            # Round-A messages into node k: m_{l->k} = B_l[:, idx_l(k)]/S_k
            # (alpha_l rides along). Build stacked coefficient vector c.
            cs = []
            for l in grp:
                idx = self.cset[l].index(k)
                m = self.b[l][:, idx] / s_k
                rho_lk = self.rho1 if (self.include_self and l == k) else self.rho2
                cs.append(self.kinv[l] @ m + (rho_lk / s_k) * self.alpha[l])
                if l != k:
                    self.comm_floats += len(m) + len(self.alpha[l])
            c = np.concatenate(cs)
            s = self.gz[k] @ c
            norm2 = max(float(c @ s), 0.0)
            if self.z_norm == "sphere":
                s = s / np.sqrt(max(norm2, 1e-30))
            elif norm2 > 1.0:
                s = s / np.sqrt(norm2)
            # Scatter segments of s back: segment for l is phi(X_l)^T z_k.
            off = 0
            for l in grp:
                n_l = len(self.xs[l])
                seg = s[off : off + n_l]
                idx = self.cset[l].index(k)
                p_new[l][:, idx] = seg
                if l != k:
                    self.comm_floats += n_l
                off += n_l
        return p_new

    def alpha_eta_update(self):
        """Eqs. (12)/(13), per node, with per-column rho."""
        for j in range(self.J):
            rho = self.rho_vec(j)
            kc = self.kc[j]
            a_mat = np.sum(rho) * kc - 2.0 * kc @ kc
            # Jitter keeps A invertible (centered Gram has a null vector).
            a_mat += 1e-8 * np.trace(np.abs(a_mat)) / len(kc) * np.eye(len(kc))
            rhs = np.sum(self.p[j] * rho[None, :] - self.b[j], axis=1)
            self.alpha[j] = np.linalg.solve(a_mat, rhs)
            kalpha = kc @ self.alpha[j]
            self.b[j] = self.b[j] + (kalpha[:, None] - self.p[j]) * rho[None, :]

    def lagrangian(self):
        """Augmented Lagrangian (8) (true L, not the relaxed U)."""
        total = 0.0
        for j in range(self.J):
            rho = self.rho_vec(j)
            kc = self.kc[j]
            ka = kc @ self.alpha[j]
            total -= float(ka @ ka)
            proj = self.kinv[j] @ self.p[j]  # K_j^{-1} phi^T z_k columns
            for col, k in enumerate(self.cset[j]):
                lin = self.b[j][:, col] @ self.alpha[j] - self.b[j][:, col] @ proj[:, col]
                quad = (
                    self.alpha[j] @ ka
                    - 2.0 * self.alpha[j] @ self.p[j][:, col]
                    + self.p[j][:, col] @ proj[:, col]
                )
                total += lin + 0.5 * rho[col] * max(quad, 0.0)
        return total

    def step(self):
        self.p = self.z_update()
        self.alpha_eta_update()

    def run(self, iters, rho2_schedule=None):
        """rho2_schedule: list of (start_iter, rho2) pairs (paper §6.1)."""
        for t in range(iters):
            if rho2_schedule:
                for start, val in rho2_schedule:
                    if t == start:
                        self.rho2 = val
            self.step()
        return self.alpha
