# pytest: Layer-2 graphs vs straightforward NumPy — validates the ADMM
# update algebra that the Rust coordinator will drive through the AOT
# artifacts.
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from tests import ref_dkpca as refa

jax.config.update("jax_platform_name", "cpu")


def _sym_psd(rng, n):
    a = rng.standard_normal((n, n))
    return (a @ a.T / n).astype(np.float32)


class TestAdmmStep:
    def _numpy_step(self, kj, ainv, p, b, rho):
        rhs = np.sum(p * rho[None, :] - b, axis=1)
        alpha = ainv @ rhs
        b_next = b + (kj @ alpha)[:, None] * rho[None, :] - p * rho[None, :]
        return alpha, b_next

    def test_matches_numpy(self):
        rng = np.random.default_rng(0)
        n, d = 17, 4
        kj = _sym_psd(rng, n)
        ainv = _sym_psd(rng, n)
        p = rng.standard_normal((n, d)).astype(np.float32)
        b = rng.standard_normal((n, d)).astype(np.float32)
        rho = np.array([100.0, 10.0, 10.0, 10.0], dtype=np.float32)
        a_got, b_got = model.admm_step(kj, ainv, p, b, rho)
        a_want, b_want = self._numpy_step(kj, ainv, p, b, rho)
        np.testing.assert_allclose(a_got, a_want, rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(b_got, b_want, rtol=1e-4, atol=1e-4)

    def test_zero_multiplier_fixed_point(self):
        # With P = Kj alpha 1^T and B chosen so rhs reproduces alpha, the
        # eta-update leaves B unchanged (primal feasibility => dual fixed).
        rng = np.random.default_rng(1)
        n, d = 11, 3
        kj = _sym_psd(rng, n)
        rho = np.full(d, 7.0, dtype=np.float32)
        ssum = float(rho.sum())
        a_mat = ssum * kj - 2.0 * kj @ kj
        a_mat += 1e-6 * np.eye(n, dtype=np.float32)
        ainv = np.linalg.inv(a_mat).astype(np.float32)
        alpha = rng.standard_normal(n).astype(np.float32)
        p = np.tile((kj @ alpha)[:, None], (1, d)).astype(np.float32)
        b = np.zeros((n, d), dtype=np.float32)
        a_new, b_new = model.admm_step(kj, ainv, p, b, rho)
        np.testing.assert_allclose(b_new, (kj @ np.asarray(a_new))[:, None] * rho - p * rho, atol=1e-4)

    @settings(max_examples=20, deadline=None)
    @given(
        n=st.integers(2, 40),
        d=st.integers(1, 8),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis(self, n, d, seed):
        rng = np.random.default_rng(seed)
        kj = _sym_psd(rng, n)
        ainv = _sym_psd(rng, n)
        p = rng.standard_normal((n, d)).astype(np.float32)
        b = rng.standard_normal((n, d)).astype(np.float32)
        rho = rng.uniform(1.0, 100.0, d).astype(np.float32)
        a_got, b_got = model.admm_step(kj, ainv, p, b, rho)
        a_want, b_want = self._numpy_step(kj, ainv, p, b, rho)
        np.testing.assert_allclose(a_got, a_want, rtol=1e-3, atol=1e-3)
        np.testing.assert_allclose(b_got, b_want, rtol=1e-3, atol=1e-2)


class TestZStep:
    def test_inside_ball_unscaled(self):
        rng = np.random.default_rng(2)
        n = 13
        g = _sym_psd(rng, n) * 1e-4  # tiny Gram -> ||z||^2 < 1
        c = rng.standard_normal(n).astype(np.float32)
        s, norm2 = model.z_step(g, c)
        np.testing.assert_allclose(s, g @ c, rtol=1e-5, atol=1e-6)
        assert float(norm2) <= 1.0

    def test_outside_ball_projected(self):
        rng = np.random.default_rng(3)
        n = 9
        g = _sym_psd(rng, n) * 50.0
        c = rng.standard_normal(n).astype(np.float32)
        s, norm2 = model.z_step(g, c)
        assert float(norm2) > 1.0
        np.testing.assert_allclose(
            np.asarray(s), (g @ c) / np.sqrt(float(norm2)), rtol=1e-4, atol=1e-5
        )

    def test_norm2_is_quadratic_form(self):
        rng = np.random.default_rng(4)
        n = 21
        g = _sym_psd(rng, n)
        c = rng.standard_normal(n).astype(np.float32)
        _, norm2 = model.z_step(g, c)
        np.testing.assert_allclose(float(norm2), float(c @ g @ c), rtol=1e-4)

    def test_negative_norm_clamped(self):
        # Indefinite (centered) Gram can push c^T G c below zero.
        g = jnp.asarray([[-1.0, 0.0], [0.0, -1.0]], dtype=jnp.float32)
        c = jnp.asarray([1.0, 1.0], dtype=jnp.float32)
        _, norm2 = model.z_step(g, c)
        assert float(norm2) == 0.0


class TestPowerIter:
    def test_converges_to_top_eigvec(self):
        rng = np.random.default_rng(5)
        n = 30
        k = _sym_psd(rng, n)
        v = rng.standard_normal(n).astype(np.float32)
        v /= np.linalg.norm(v)
        for _ in range(300):
            v, rayleigh = model.power_iter_step(k, v)
        w, vec = np.linalg.eigh(k.astype(np.float64))
        assert abs(abs(np.asarray(v) @ vec[:, -1]) - 1.0) < 1e-3
        assert abs(float(rayleigh) - w[-1]) < 1e-3 * abs(w[-1])

    def test_unit_norm_output(self):
        rng = np.random.default_rng(6)
        k = _sym_psd(rng, 12)
        v = rng.standard_normal(12).astype(np.float32)
        v2, _ = model.power_iter_step(k, v)
        np.testing.assert_allclose(np.linalg.norm(np.asarray(v2)), 1.0, rtol=1e-5)


class TestSimilarity:
    def test_self_similarity_is_one(self):
        rng = np.random.default_rng(7)
        n = 15
        k = _sym_psd(rng, n)
        a = rng.standard_normal(n).astype(np.float32)
        sim = model.similarity(a, k, k, a, k)
        np.testing.assert_allclose(float(sim), 1.0, rtol=1e-4)

    def test_sign_invariant(self):
        rng = np.random.default_rng(8)
        n = 15
        k = _sym_psd(rng, n)
        a = rng.standard_normal(n).astype(np.float32)
        b = rng.standard_normal(n).astype(np.float32)
        s1 = model.similarity(a, k, k, b, k)
        s2 = model.similarity(a, k, k, -b, k)
        np.testing.assert_allclose(float(s1), float(s2), rtol=1e-6)

    def test_matches_numpy_reference(self):
        rng = np.random.default_rng(9)
        xs = [rng.standard_normal((12, 3)) for _ in range(2)]
        gamma = 0.5
        alpha_gt, _, kg, xg = refa.central_kpca(xs, gamma)
        kj = refa.center_gram(refa.rbf_gram(xs[0], xs[0], gamma))
        kx = refa.center_gram(refa.rbf_gram(xs[0], xg, gamma))
        a = rng.standard_normal(12)
        want = refa.similarity(a, kx, kj, alpha_gt, kg)
        got = model.similarity(
            a.astype(np.float32),
            kx.astype(np.float32),
            kj.astype(np.float32),
            alpha_gt.astype(np.float32),
            kg.astype(np.float32),
        )
        np.testing.assert_allclose(float(got), want, rtol=1e-3)
