# Layer-2 JAX compute graphs for DKPCA (paper Alg. 1) — build-time only.
#
# Everything here is lowered once to HLO text by aot.py; the Rust runtime
# (rust/src/runtime/) loads and executes the artifacts on the PJRT CPU
# client. The graphs call the Layer-1 Pallas kernels (kernels/rbf.py,
# kernels/center.py) so the kernels lower into the same HLO modules.
#
# Per-node quantities (node j, N = N_j samples, D = |Omega_j| neighbors):
#   Kj   (N, N)  centered local Gram (+ eps jitter so it is invertible —
#                centering puts the all-ones vector in the null space)
#   B    (N, D)  phi(X_j)^T eta_j, the kernelized multiplier (paper (13))
#   P    (N, D)  phi(X_j)^T Z xi_j, projections of neighbors' z received
#   Ainv (N, N)  (rho * D * Kj - 2 Kj^2)^{-1}, constant per rho stage
#
# ADMM updates implemented here:
#   alpha-update (12):  alpha' = Ainv @ (rho * P - B) @ 1_D
#   eta-update   (13):  B'     = B + rho * (Kj @ alpha' 1_D^T - P)
#   z-update (10)/(11): given the stacked neighbor coefficient vector c
#                       (concatenation of c_l = K_l^{-1} msg_l + alpha_l/D
#                       over l in Omega_j) and the centered Gram G of the
#                       concatenated neighbor data, s = G c gives all
#                       phi(X_l)^T z_hat_j stacked and ||z_hat||^2 = c^T s;
#                       scale by 1/||z_hat|| when the norm exceeds 1.
import jax
import jax.numpy as jnp

from compile.kernels import center as center_k
from compile.kernels import rbf as rbf_k


def gram_rbf_centered(x, y, gamma):
    """Centered RBF Gram block between datasets x (n, m) and y (p, m)."""
    return center_k.center_gram(rbf_k.rbf_gram(x, y, gamma))


def admm_step(kj, ainv, p, b, rho):
    """Fused alpha-update (12) + eta-update (13) for one node.

    `rho` is a (D,) runtime input carrying one penalty per constraint
    column: the paper's §6.1 tuning uses rho^(1) = 100 for the
    self-constraint and a scheduled rho^(2) (10 -> 50 -> 100) for
    neighbor constraints, so the per-column generalisation of (12)/(13)
    is required (Ainv = (sum(rho) Kj - 2 Kj^2)^{-1} is recomputed
    host-side whenever the schedule advances). Returns (alpha', B').
    """
    rho = jnp.asarray(rho, jnp.float32)
    rhs = jnp.sum(p * rho[None, :] - b, axis=1)  # sum_k rho_k P_k - B_k
    alpha = ainv @ rhs
    kalpha = kj @ alpha
    b_next = b + (kalpha[:, None] - p) * rho[None, :]
    return alpha, b_next


def z_step(g, c):
    """z-update (10) + feasibility projection (11), kernelized.

    g: (DN, DN) centered Gram over the concatenated neighbor data of node
    j; c: (DN,) stacked coefficients so that z_hat = phi(X_nb) c.
    Returns (s, norm2) where s stacks phi(X_l)^T z_j for every neighbor l
    (already rescaled onto the unit ball) and norm2 = ||z_hat||^2.
    """
    s = g @ c
    norm2 = jnp.dot(c, s)
    # Centered Grams can make norm2 slightly negative for degenerate c.
    norm2 = jnp.maximum(norm2, 0.0)
    scale = jnp.where(norm2 > 1.0, jax.lax.rsqrt(norm2 + 1e-30), 1.0)
    return s * scale, norm2


def power_iter_step(k, v):
    """One power-iteration step for the central-kPCA baseline.

    Returns (v', rayleigh) with v' = K v / ||K v|| and rayleigh = v^T K v.
    """
    w = k @ v
    rayleigh = jnp.dot(v, w)
    nrm = jnp.linalg.norm(w)
    return w / jnp.maximum(nrm, 1e-30), rayleigh


def similarity(alpha_j, k_cross, kj, alpha_gt, k_global):
    """Paper §6.1 similarity of w_j = phi(X_j) alpha_j to the ground truth.

    |alpha_j^T K(X_j, X) alpha_gt| / sqrt((alpha_j^T Kj alpha_j)
    (alpha_gt^T K alpha_gt)); absolute value because the eigvector sign is
    arbitrary.
    """
    num = jnp.abs(alpha_j @ (k_cross @ alpha_gt))
    den = jnp.sqrt(
        jnp.abs(alpha_j @ (kj @ alpha_j)) * jnp.abs(alpha_gt @ (k_global @ alpha_gt))
    )
    return num / jnp.maximum(den, 1e-30)
