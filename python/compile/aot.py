# AOT lowering: JAX (L2) + Pallas (L1) graphs -> HLO TEXT artifacts.
#
# Interchange format is HLO *text*, NOT lowered.compile()/.serialize():
# jax >= 0.5 emits HloModuleProtos with 64-bit instruction ids which the
# xla crate's xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the
# text parser reassigns ids and round-trips cleanly. See
# /opt/xla-example/gen_hlo.py and README gotchas.
#
# Usage (from python/):  python -m compile.aot --outdir ../artifacts
#
# Emits one .hlo.txt per (graph, shape) in the hot-shape manifest below,
# plus manifest.json describing inputs/outputs so the Rust runtime
# (rust/src/runtime/registry.rs) can key executables by (op, shape).
# Python never runs again after this: the Rust binary is self-contained.
import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model

F32 = "f32"


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (return_tuple so the Rust
    side always unwraps a tuple, see load_hlo.rs reference)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(*shape):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.float32)


def build_artifact_set(feat_dim, gram_shapes, admm_shapes, z_dims, power_dims):
    """Return [(name, fn, arg_specs, meta)] for the hot-shape manifest."""
    arts = []
    for n, p in gram_shapes:
        arts.append(
            (
                f"gram_rbf_centered_{n}x{p}_m{feat_dim}",
                model.gram_rbf_centered,
                (spec(n, feat_dim), spec(p, feat_dim), spec()),
                {
                    "op": "gram_rbf_centered",
                    "n": n,
                    "p": p,
                    "m": feat_dim,
                    "inputs": [[n, feat_dim], [p, feat_dim], []],
                    "outputs": [[n, p]],
                },
            )
        )
    for n, d in admm_shapes:
        arts.append(
            (
                f"admm_step_n{n}_d{d}",
                model.admm_step,
                (spec(n, n), spec(n, n), spec(n, d), spec(n, d), spec(d)),
                {
                    "op": "admm_step",
                    "n": n,
                    "d": d,
                    "inputs": [[n, n], [n, n], [n, d], [n, d], [d]],
                    "outputs": [[n], [n, d]],
                },
            )
        )
    for dn in z_dims:
        arts.append(
            (
                f"z_step_dn{dn}",
                model.z_step,
                (spec(dn, dn), spec(dn)),
                {
                    "op": "z_step",
                    "dn": dn,
                    "inputs": [[dn, dn], [dn]],
                    "outputs": [[dn], []],
                },
            )
        )
    for n in power_dims:
        arts.append(
            (
                f"power_iter_n{n}",
                model.power_iter_step,
                (spec(n, n), spec(n)),
                {
                    "op": "power_iter",
                    "n": n,
                    "inputs": [[n, n], [n]],
                    "outputs": [[n], []],
                },
            )
        )
    return arts


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--outdir", default="../artifacts")
    ap.add_argument("--feat-dim", type=int, default=784)
    ap.add_argument(
        "--small",
        action="store_true",
        help="tiny shapes only (fast CI / test path)",
    )
    args = ap.parse_args()

    if args.small:
        gram_shapes = [(16, 16), (16, 64)]
        admm_shapes = [(16, 4)]
        z_dims = [64]
        power_dims = [64]
    else:
        # Hot shapes of the paper's experiments: N_j = 100 samples/node,
        # |Omega| = 4 neighbors (plus the self-constraint column, so the
        # constraint count is D = |Omega|+1 = 5 and the z-step Gram spans
        # the (|Omega|+1)-node group, dn = 500), J = 20 nodes central
        # baseline (N = 2000); Fig. 4 sweeps N_j in {40..300}.
        gram_shapes = [(100, 100), (100, 500), (500, 500), (2000, 2000)]
        admm_shapes = [(40, 5), (100, 3), (100, 5), (100, 9), (200, 5), (300, 5)]
        z_dims = [200, 300, 500, 900, 1000, 1500]
        power_dims = [2000]

    os.makedirs(args.outdir, exist_ok=True)
    manifest = {"feat_dim": args.feat_dim, "dtype": F32, "artifacts": []}
    arts = build_artifact_set(
        args.feat_dim, gram_shapes, admm_shapes, z_dims, power_dims
    )
    for name, fn, arg_specs, meta in arts:
        lowered = jax.jit(fn).lower(*arg_specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(args.outdir, fname), "w") as f:
            f.write(text)
        meta = dict(meta, name=name, file=fname)
        manifest["artifacts"].append(meta)
        print(f"  lowered {name}: {len(text)} chars")
    with open(os.path.join(args.outdir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {len(arts)} artifacts + manifest.json to {args.outdir}")


if __name__ == "__main__":
    main()
