# Pure-jnp oracles for the Layer-1 Pallas kernels.
#
# These are the CORE correctness references: python/tests/test_kernels.py
# sweeps shapes/dtypes (hypothesis) and asserts the Pallas outputs match
# these to tight tolerance. They are also reused by the Layer-2 model
# tests as the "obviously correct" implementation.
import jax.numpy as jnp


def rbf_gram_ref(x, y, gamma):
    """exp(-gamma * ||x_i - y_j||^2), computed the naive broadcast way."""
    x = x.astype(jnp.float32)
    y = y.astype(jnp.float32)
    d2 = jnp.sum((x[:, None, :] - y[None, :, :]) ** 2, axis=-1)
    return jnp.exp(-jnp.float32(gamma) * d2)


def center_gram_ref(k):
    """Paper §6.1 double-centering: K - 1K/m - K1/n + 1K1/(mn)."""
    k = k.astype(jnp.float32)
    m, n = k.shape
    ones_m = jnp.ones((m, m), dtype=jnp.float32)
    ones_n = jnp.ones((n, n), dtype=jnp.float32)
    return k - ones_m @ k / m - k @ ones_n / n + ones_m @ k @ ones_n / (m * n)
