# Layer-1 Pallas kernel: tiled double-centering of a (cross-)Gram block.
#
# Paper §6.1: K_c = K - (1/m) 1_m K - (1/n) K 1_n + (1/(mn)) 1_m K 1_n
# for K in R^{m x n} (1_k is the k x k all-ones matrix), i.e. subtract the
# column means, subtract the row means, add back the grand mean. The
# means are a cheap O(nm) reduction prologue done in plain jnp; the O(nm)
# broadcast-subtract main pass is the tiled Pallas kernel (pure VPU work).
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = (128, 128)


def _center_kernel(k_ref, rm_ref, cm_ref, gm_ref, o_ref):
    """One (bn, bp) tile: K - row_mean - col_mean + grand_mean."""
    k = k_ref[...]          # (bn, bp)
    rm = rm_ref[...]        # (bn, 1)  mean over columns, per row
    cm = cm_ref[...]        # (1, bp)  mean over rows, per column
    gm = gm_ref[0, 0]       # ()       grand mean
    o_ref[...] = k - rm - cm + gm


def _pad2(a: jax.Array, bn: int, bp: int) -> jax.Array:
    pn = (-a.shape[0]) % bn
    pp = (-a.shape[1]) % bp
    if pn == 0 and pp == 0:
        return a
    return jnp.pad(a, ((0, pn), (0, pp)))


@functools.partial(jax.jit, static_argnames=("block",))
def center_gram(k: jax.Array, block=DEFAULT_BLOCK) -> jax.Array:
    """Double-centered Gram block, same shape as `k` ((n, p))."""
    n, p = k.shape
    bn, bp = block
    bn = min(bn, max(n, 1))
    bp = min(bp, max(p, 1))
    k = k.astype(jnp.float32)
    # Reduction prologue (cheap): per-row / per-column / grand means.
    rm = jnp.mean(k, axis=1, keepdims=True)   # (n, 1)
    cm = jnp.mean(k, axis=0, keepdims=True)   # (1, p)
    gm = jnp.mean(k).reshape(1, 1)            # (1, 1)
    kp = _pad2(k, bn, bp)
    rmp = _pad2(rm, bn, 1)
    cmp_ = _pad2(cm, 1, bp)
    grid = (kp.shape[0] // bn, kp.shape[1] // bp)
    out = pl.pallas_call(
        _center_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, bp), lambda i, j: (i, j)),
            pl.BlockSpec((bn, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((1, bp), lambda i, j: (0, j)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bn, bp), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct(kp.shape, jnp.float32),
        interpret=True,
    )(kp, rmp, cmp_, gm)
    return out[:n, :p]
