# Layer-1 Pallas kernel: VMEM-tiled RBF Gram matrix.
#
# The paper's compute hot-spot is Gram assembly K[i,j] = exp(-gamma *
# ||x_i - y_j||^2) (local K_j, neighbor cross-blocks K_(l,l'), and the
# central-baseline global Gram). On TPU the squared distance is
# reorganised as ||x||^2 + ||y||^2 - 2 x@y.T so the O(n*p*m) inner term
# is a single MXU-shaped matmul per tile; the rank-1 norm corrections and
# exp run on the VPU. BlockSpec tiles the (n, p) output; each step keeps
# one (bn, m) and one (bp, m) feature stripe resident in VMEM.
#
# interpret=True everywhere: the CPU PJRT plugin cannot execute Mosaic
# custom-calls (see DESIGN.md §Hardware-Adaptation). Numerics are
# validated against kernels/ref.py by python/tests/test_kernels.py.
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile: (128, 128) output block. With m = 784 features the VMEM
# working set is 2 * 128*784*4B (stripes) + 128*128*4B (out) ~ 0.85 MiB,
# far under the ~16 MiB VMEM budget, leaving room for double-buffering.
DEFAULT_BLOCK = (128, 128)


def _rbf_gram_kernel(x_ref, y_ref, g_ref, o_ref):
    """One (bn, bp) tile of the RBF Gram matrix."""
    x = x_ref[...]  # (bn, m) stripe
    y = y_ref[...]  # (bp, m) stripe
    gamma = g_ref[0, 0]
    xx = jnp.sum(x * x, axis=1, keepdims=True)  # (bn, 1)
    yy = jnp.sum(y * y, axis=1, keepdims=True)  # (bp, 1)
    # MXU tile: contract the feature dimension of both stripes.
    xy = jax.lax.dot_general(
        x, y, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    d2 = xx + jnp.transpose(yy) - 2.0 * xy
    # Guard tiny negative values from cancellation so exp stays <= 1.
    o_ref[...] = jnp.exp(-gamma * jnp.maximum(d2, 0.0))


def _pad_rows(a: jax.Array, multiple: int) -> jax.Array:
    n = a.shape[0]
    pad = (-n) % multiple
    if pad == 0:
        return a
    return jnp.pad(a, ((0, pad), (0, 0)))


@functools.partial(jax.jit, static_argnames=("block",))
def rbf_gram(x: jax.Array, y: jax.Array, gamma, block=DEFAULT_BLOCK) -> jax.Array:
    """Uncentered RBF Gram exp(-gamma * ||x_i - y_j||^2), shape (n, p).

    x: (n, m), y: (p, m), gamma: scalar (runtime input, not baked into the
    artifact so the Rust side can sweep bandwidths without re-lowering).
    Inputs are zero-padded up to the tile multiple and the result sliced
    back, so arbitrary n/p are supported.
    """
    n, m = x.shape
    p, _ = y.shape
    bn, bp = block
    bn = min(bn, max(n, 1))
    bp = min(bp, max(p, 1))
    xp = _pad_rows(x.astype(jnp.float32), bn)
    yp = _pad_rows(y.astype(jnp.float32), bp)
    g = jnp.asarray(gamma, dtype=jnp.float32).reshape(1, 1)
    grid = (xp.shape[0] // bn, yp.shape[0] // bp)
    out = pl.pallas_call(
        _rbf_gram_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, m), lambda i, j: (i, 0)),
            pl.BlockSpec((bp, m), lambda i, j: (j, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bn, bp), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((xp.shape[0], yp.shape[0]), jnp.float32),
        interpret=True,
    )(xp, yp, g)
    return out[:n, :p]
