//! Top-k training: extract a 3-component kernel-PCA subspace fully
//! decentralized (deflation-based multik ADMM), compare the subspace
//! to the exact central top-3 and the local-only baseline, then export
//! the k-column model and serve a held-out batch — three projection
//! coordinates per point through the unchanged serve engine.
//!
//!     cargo run --release --example topk_training
//!
//! After each consensus pass converges, every node deflates its Gram
//! copies with the agreed component (one N-float exchange per directed
//! edge) and re-runs the pass on the deflated operator — the top
//! direction of which is the next principal component.

use dkpca::admm::AdmmConfig;
use dkpca::backend::NativeBackend;
use dkpca::central::{central_kpca, local_kpca_topk, subspace_affinity};
use dkpca::data::synth::{blob_centers, sample_blobs, BlobSpec};
use dkpca::data::{NoiseModel, Rng};
use dkpca::kernels::Kernel;
use dkpca::model::DkpcaModel;
use dkpca::multik::MultiKpcaSolver;
use dkpca::serve::{ProjectionEngine, ProjectionPath, ProjectionRequest};
use dkpca::topology::Graph;

fn main() {
    let k = 3usize;

    // 1. Data: six nodes, 25 samples each, one shared 4-cluster
    //    mixture (top-3 extraction needs at least 4 clusters for the
    //    components to be spectrally separated).
    let spec = BlobSpec { n_classes: 4, ..Default::default() };
    let centers = blob_centers(&spec, 42);
    let mut rng = Rng::new(43);
    let xs: Vec<_> = (0..6)
        .map(|_| sample_blobs(&spec, &centers, 25, None, &mut rng).0)
        .collect();
    let graph = Graph::ring(6, 2);
    let kernel = Kernel::Rbf { gamma: 0.1 };

    // 2. Train k components: each pass runs to the decentralized stop
    //    rule, then the network deflates and re-seeds. Sphere z-rule:
    //    deflation flattens the spectrum, where the ball rule drifts.
    let cfg = AdmmConfig {
        max_iters: 300,
        tol: 1e-8,
        seed: 1,
        z_norm: dkpca::admm::ZNorm::Sphere,
        ..Default::default()
    };
    let mut solver =
        MultiKpcaSolver::new(&xs, &graph, &kernel, &cfg, NoiseModel::None, 0, k);
    let result = solver.run(&NativeBackend);
    println!(
        "per-component iterations: {:?} (converged: {:?})",
        result.per_component_iterations, result.converged
    );
    println!(
        "training traffic: {} floats (iteration protocol + deflation exchanges)",
        result.comm_floats
    );

    // 3. Subspace quality per node: principal-angle affinity to the
    //    exact central top-k, against the local-only baseline.
    let central = central_kpca(&xs, &kernel);
    println!("\nnode | local top-{k} affinity | DKPCA top-{k} affinity");
    println!("-----+---------------------+--------------------");
    for (j, x) in xs.iter().enumerate() {
        let local = subspace_affinity(&local_kpca_topk(x, &kernel, k), x, &central, k, &kernel);
        let dkpca = subspace_affinity(&result.alphas[j], x, &central, k, &kernel);
        println!("   {j} |              {local:.4} |             {dkpca:.4}");
    }

    // 4. Export the k-column model, reload, and serve: every projection
    //    now carries k coordinates per point.
    let artifact_path = std::env::temp_dir().join("dkpca_topk_training.dkpm");
    solver.to_model().save(&artifact_path).expect("save model artifact");
    let model = DkpcaModel::load(&artifact_path).expect("load model artifact");
    println!(
        "\nmodel artifact: {} nodes x {} components, {} bytes",
        model.n_nodes(),
        model.nodes[0].n_components(),
        std::fs::metadata(&artifact_path).map(|m| m.len()).unwrap_or(0),
    );

    let held_out = sample_blobs(&spec, &centers, 6, None, &mut rng).0;
    let engine = ProjectionEngine::new(model, 2);
    let served = engine
        .project(ProjectionRequest {
            node: 0,
            batch: held_out,
            path: ProjectionPath::Exact,
        })
        .expect("exact projection");
    println!("\nheld-out projections through node 0 (k = {k} coordinates/point):");
    for i in 0..served.outputs.rows() {
        let coords: Vec<String> =
            (0..k).map(|c| format!("{:>9.5}", served.outputs[(i, c)])).collect();
        println!("    point {i}: [{}]", coords.join(", "));
    }
    let _ = std::fs::remove_file(&artifact_path);
}
