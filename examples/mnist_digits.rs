//! The paper's §6 workload: 20 nodes x 100 MNIST-like digit images
//! (classes {0, 3, 5, 8}, 784-d), ring topology with 4 neighbors,
//! rho^(1) = 100 and the rho^(2) 10 -> 50 -> 100 schedule.
//!
//!     cargo run --release --example mnist_digits
//!
//! Prints the Fig. 3/4-style comparison: local-only vs DKPCA vs the
//! neighbor-gather baseline, plus running times.

use std::sync::Arc;

use dkpca::backend::NativeBackend;
use dkpca::central::{local_kpca, neighbor_gather_kpca, similarity};
use dkpca::config::ExperimentConfig;
use dkpca::coordinator::run_decentralized;
use dkpca::data::NoiseModel;
use dkpca::experiments::{build_env, central_kpca_power, paper_admm};
use dkpca::metrics::{Stats, Stopwatch};

fn main() {
    let cfg = ExperimentConfig { nodes: 20, samples_per_node: 100, seed: 7, ..Default::default() };
    let env = build_env(&cfg);
    println!(
        "dataset: J={} nodes x N_j={} images of {} pixels, |Omega|={}",
        cfg.nodes,
        cfg.samples_per_node,
        env.xs[0].cols(),
        env.graph.degree(0)
    );

    // Central ground truth (timed — this is what Fig. 3 beats).
    let sw = Stopwatch::start();
    let central = central_kpca_power(&env.xs, &env.kernel, 500);
    let central_secs = sw.elapsed_secs();

    // DKPCA on the parallel coordinator.
    let admm = paper_admm(cfg.seed, 40);
    let sw = Stopwatch::start();
    let rep = run_decentralized(
        &env.xs,
        &env.graph,
        &env.kernel,
        &admm,
        NoiseModel::None,
        cfg.seed,
        Arc::new(NativeBackend),
    );
    let dkpca_secs = sw.elapsed_secs();

    let dkpca: Vec<f64> = rep
        .alphas
        .iter()
        .zip(&env.xs)
        .map(|(a, x)| similarity(a, x, &central, &env.kernel))
        .collect();
    let local: Vec<f64> = env
        .xs
        .iter()
        .map(|x| similarity(&local_kpca(x, &env.kernel), x, &central, &env.kernel))
        .collect();
    let gather: Vec<f64> = (0..cfg.nodes)
        .map(|j| {
            let (pool, alpha) =
                neighbor_gather_kpca(&env.xs, j, env.graph.neighbors(j), &env.kernel);
            similarity(&alpha, &pool, &central, &env.kernel)
        })
        .collect();

    println!("\nsimilarity to central kPCA (alpha_gt):");
    println!("  local-only     : {}", Stats::from(&local));
    println!("  neighbor-gather: {}", Stats::from(&gather));
    println!("  DKPCA (Alg. 1) : {}", Stats::from(&dkpca));
    println!("\nrunning time:");
    println!("  central kPCA  : {central_secs:.3}s (Gram {0}x{0} + power iteration)", cfg.nodes * cfg.samples_per_node);
    println!("  DKPCA wall    : {dkpca_secs:.3}s ({} iterations, {} node threads)", rep.iterations, cfg.nodes);
    let node_mean =
        rep.node_compute_secs.iter().sum::<f64>() / rep.node_compute_secs.len() as f64;
    println!("  per-node CPU  : {node_mean:.3}s (the deployable decentralized metric)");
    println!(
        "\ncommunication: {:.1}k floats/node total ({} iterations, O(|Omega| N) per iteration)",
        rep.per_node_sent.iter().sum::<u64>() as f64 / cfg.nodes as f64 / 1e3,
        rep.iterations
    );
}
