//! Privacy-preserving training via the feature-space setup exchange
//! (the paper's §7 future-work direction, now a config switch):
//!
//!     cargo run --release --example private_training
//!
//! In the classic Alg. 1 setup every node ships its raw samples to all
//! neighbors — `N*M` floats per directed edge and total disclosure. In
//! `SetupExchange::RffFeatures` mode the nodes agree on a shared seed,
//! sample the same random-Fourier feature map, and transmit only the
//! featurized `z(X_j)`: raw readings never leave their node, the setup
//! traffic drops from `N*M` to `N*D` (here 784-dim images vs 256
//! features — a 3x cut), and every Gram block downstream is assembled
//! from the transmitted features. The run compares both modes on the
//! same network, then serves a held-out batch through the feature-space
//! model — the exported artifact is a plain linear-kernel model over
//! `z(x)`, so the serving stack needs no changes at all.

use dkpca::admm::{AdmmConfig, DkpcaSolver, SetupExchange};
use dkpca::backend::NativeBackend;
use dkpca::central::{central_kpca, mean_similarity};
use dkpca::data::mnist_like::{self, PAPER_DIGITS};
use dkpca::data::{partition, NoiseModel, Strategy};
use dkpca::kernels::Kernel;
use dkpca::serve::{ProjectionEngine, ProjectionPath, ProjectionRequest};
use dkpca::topology::Graph;

fn main() {
    // 6 nodes, 25 MNIST-like 784-dim images each, ring network.
    let (j, n) = (6usize, 25usize);
    let (x, labels) = mnist_like::generate(&PAPER_DIGITS, j * n + 5, 17);
    let labels: Vec<usize> = labels.into_iter().map(|l| l as usize).collect();
    let held_out = x.block(j * n, j * n + 5, 0, x.cols());
    let train = x.block(0, j * n, 0, x.cols());
    let xs = partition(&train, &labels[..j * n], j, Strategy::Even, 5151);
    let graph = Graph::ring(j, 1);
    let kernel = Kernel::Rbf { gamma: 0.02 };
    let central = central_kpca(&xs, &kernel);

    println!("setup mode | per-edge setup floats | mean similarity to central");
    println!("-----------+-----------------------+---------------------------");
    let directed_edges = (2 * graph.edge_count()) as u64;

    // Raw-data mode: Alg. 1 as printed — neighbors see every image.
    let raw_cfg = AdmmConfig { max_iters: 30, seed: 1, ..Default::default() };
    let mut raw = DkpcaSolver::new(&xs, &graph, &kernel, &raw_cfg, NoiseModel::None, 0);
    let raw_res = raw.run(&NativeBackend);
    let raw_sim = mean_similarity(&raw_res.alphas, &xs, &central, &kernel);
    println!(
        "raw data   | {:>21} | {raw_sim:.4}",
        raw_res.setup_floats / directed_edges
    );

    // Feature-space mode: neighbors only ever see z(X_j).
    let dim = 256;
    let rff_cfg = AdmmConfig {
        max_iters: 30,
        seed: 1,
        setup: SetupExchange::RffFeatures { dim, seed: 99 },
        ..Default::default()
    };
    let mut rff = DkpcaSolver::new(&xs, &graph, &kernel, &rff_cfg, NoiseModel::None, 0);
    let rff_res = rff.run(&NativeBackend);
    let rff_sim = mean_similarity(&rff_res.alphas, &xs, &central, &kernel);
    println!(
        "rff-{dim}    | {:>21} | {rff_sim:.4}",
        rff_res.setup_floats / directed_edges
    );

    // Serve held-out points through the feature-space model: the
    // artifact is a linear-kernel model over z(x), so the PR-1 serving
    // stack works unchanged — the client featurizes with the shared map.
    let model = rff.to_model();
    let map = rff.rff_map().expect("feature mode exposes the shared map");
    let engine = ProjectionEngine::new(model, 2);
    let served = engine
        .project(ProjectionRequest {
            node: 0,
            batch: map.features(&held_out),
            path: ProjectionPath::Exact,
        })
        .expect("serve featurized batch");
    println!("\nheld-out projections through node 0 (feature-space model):");
    for i in 0..served.outputs.rows() {
        println!("  image {i}: {:>9.5}", served.outputs[(i, 0)]);
    }
    println!(
        "\nRaw images never crossed an edge: each neighbor received the\n\
         {dim}-dim shared-seed features z(X_j) instead of the 784-dim\n\
         pixels, and every Gram block was assembled from those\n\
         transmitted features."
    );
}
