//! Wireless-sensor-network scenario (the paper's motivating
//! application class): heterogeneous nodes, noisy channels, and one
//! faulty sensor whose data collapses onto a line (Fig. 1(c)).
//!
//!     cargo run --release --example sensor_network
//!
//! Demonstrates why the projection consensus constraint matters: the
//! strict-consensus view would be crippled by the faulty node, while
//! DKPCA (with the sphere z-rule) keeps every healthy node close to
//! the global solution — over channels with Gaussian noise.

use dkpca::admm::{AdmmConfig, DkpcaSolver, ZNorm};
use dkpca::backend::NativeBackend;
use dkpca::central::{central_kpca, local_kpca, similarity};
use dkpca::data::synth::{blob_centers, degenerate_data, sample_blobs, BlobSpec};
use dkpca::data::{NoiseModel, Rng};
use dkpca::kernels::Kernel;
use dkpca::topology::Graph;

fn main() {
    // 8 sensors observing a shared 6-D field, 25 readings each;
    // sensor 0 is faulty: its readings collapse onto a line (rank 1).
    let spec = BlobSpec { dim: 6, ..Default::default() };
    let centers = blob_centers(&spec, 11);
    let mut rng = Rng::new(12);
    let mut xs: Vec<_> = (0..8)
        .map(|_| sample_blobs(&spec, &centers, 25, None, &mut rng).0)
        .collect();
    xs[0] = degenerate_data(6, 25, 1, 1.0, &mut rng);

    // Sensors form a ring; links add Gaussian channel noise.
    let graph = Graph::ring(8, 1);
    let kernel = Kernel::Rbf { gamma: 0.1 };
    let noise = NoiseModel::Gaussian { sigma: 0.01 };

    let central = central_kpca(&xs, &kernel);
    let report = |label: &str, alphas: &[Vec<f64>]| {
        let sims: Vec<f64> = alphas
            .iter()
            .zip(&xs)
            .map(|(a, x)| similarity(a, x, &central, &kernel))
            .collect();
        let healthy = sims[1..].iter().sum::<f64>() / 7.0;
        println!("{label:<22} healthy-mean {healthy:.4}   faulty-node {:.4}", sims[0]);
    };

    let locals: Vec<Vec<f64>> = xs.iter().map(|x| local_kpca(x, &kernel)).collect();
    report("local-only", &locals);

    for (label, z_norm) in [("DKPCA (ball, eq.11)", ZNorm::Ball), ("DKPCA (sphere)", ZNorm::Sphere)] {
        let cfg = AdmmConfig { z_norm, max_iters: 80, seed: 5, ..Default::default() };
        let mut solver = DkpcaSolver::new(&xs, &graph, &kernel, &cfg, noise, 13);
        let res = solver.run(&NativeBackend);
        report(label, &res.alphas);
    }
    println!(
        "\nWith a faulty sensor inside the consensus loop the relaxed\n\
         ball rule (11) drifts toward the trivial fixed point; the\n\
         sphere rule (the original ||z|| = 1 of problem (7)) bounds the\n\
         damage and keeps healthy sensors close to the global solution."
    );
}
