//! END-TO-END DRIVER — proves all three layers compose on a real
//! workload (DESIGN.md: the required full-system example; results are
//! recorded in EXPERIMENTS.md).
//!
//!     make artifacts && cargo run --release --example e2e_full_run
//!
//! The full stack in one run:
//!   L1  Pallas RBF-Gram + centering kernels  — inside the HLO artifacts
//!   L2  JAX ADMM/z-step/power-iteration graphs — AOT-lowered HLO text
//!   L3  Rust: 20 node actors on OS threads, message fabric, ADMM
//!       protocol, executing the hot ops through the PJRT CPU client
//!       (native fallback for uncovered shapes).
//!
//! Workload: the paper's §6 setting — J = 20 nodes x N_j = 100
//! MNIST-like digit images (classes {0,3,5,8}), ring with |Omega| = 4,
//! rho^(1) = 100, rho^(2) in {10, 50, 100}. Reports the paper's
//! headline metrics: similarity to central kPCA, running time, and
//! communication volume.

use std::sync::Arc;

use dkpca::backend::NativeBackend;
use dkpca::central::{local_kpca, similarity};
use dkpca::config::ExperimentConfig;
use dkpca::coordinator::run_decentralized;
use dkpca::data::NoiseModel;
use dkpca::experiments::{build_env, central_kpca_power, paper_admm};
use dkpca::metrics::{Stats, Stopwatch};
use dkpca::runtime::{default_artifacts_dir, PjrtBackend};

fn main() {
    println!("=== DKPCA end-to-end driver (L1 Pallas + L2 JAX + L3 Rust) ===\n");

    // ---- Backend: AOT artifacts through PJRT (hybrid dispatch: the
    // measured marshalling crossover is ~10 MFLOP, so Gram-sized ops go
    // to the artifacts and sub-ms ops stay native; see §Perf). ----
    let pjrt = match PjrtBackend::new_hybrid(&default_artifacts_dir(), 1e7) {
        Ok(b) => Arc::new(b),
        Err(e) => {
            eprintln!("artifacts not built ({e}); run `make artifacts` first");
            std::process::exit(1);
        }
    };
    println!(
        "artifact registry: {} compiled graphs (feat_dim {})",
        pjrt.registry().len(),
        pjrt.registry().feat_dim
    );

    // ---- Workload (paper §6). ----
    let cfg = ExperimentConfig { nodes: 20, samples_per_node: 100, seed: 2026, ..Default::default() };
    let env = build_env(&cfg);
    println!(
        "workload: J={} x N_j={} MNIST-like digits (784-d), ring |Omega|={}\n",
        cfg.nodes,
        cfg.samples_per_node,
        env.graph.degree(0)
    );

    // ---- Central baseline (the thing the paper outruns). ----
    let sw = Stopwatch::start();
    let central = central_kpca_power(&env.xs, &env.kernel, 500);
    let central_secs = sw.elapsed_secs();

    // ---- Decentralized run: 20 threads, PJRT hot path. ----
    let admm = paper_admm(cfg.seed, 40);
    let sw = Stopwatch::start();
    let rep = run_decentralized(
        &env.xs,
        &env.graph,
        &env.kernel,
        &admm,
        NoiseModel::None,
        cfg.seed,
        pjrt.clone(),
    );
    let dkpca_secs = sw.elapsed_secs();
    let (hits, misses) = pjrt.stats();

    // ---- Metrics. ----
    let dkpca_sims: Vec<f64> = rep
        .alphas
        .iter()
        .zip(&env.xs)
        .map(|(a, x)| similarity(a, x, &central, &env.kernel))
        .collect();
    let local_sims: Vec<f64> = env
        .xs
        .iter()
        .map(|x| similarity(&local_kpca(x, &env.kernel), x, &central, &env.kernel))
        .collect();

    println!("similarity to alpha_gt (paper §6.1 metric):");
    println!("  local-only : {}", Stats::from(&local_sims));
    println!("  DKPCA      : {}", Stats::from(&dkpca_sims));
    println!("\nrunning time:");
    println!("  central kPCA : {central_secs:.3}s");
    println!("  DKPCA wall   : {dkpca_secs:.3}s ({} node threads on this host)", cfg.nodes);
    let node_mean =
        rep.node_compute_secs.iter().sum::<f64>() / rep.node_compute_secs.len() as f64;
    println!("  per-node CPU : {node_mean:.3}s  <- flat in J (paper's headline)");
    println!("\ncommunication: {} floats total; {:.0} floats/node/iter (O(|Omega| N))",
        rep.comm_floats_total,
        (rep.comm_floats_total as f64
            - (cfg.nodes * 4 * cfg.samples_per_node * 784) as f64)
            / (cfg.nodes * rep.iterations) as f64
    );
    println!("\nPJRT execution: {hits} artifact calls, {misses} native fallbacks");

    // ---- Cross-check: the PJRT-backed run agrees with pure native. ----
    let sw = Stopwatch::start();
    let rep_native = run_decentralized(
        &env.xs,
        &env.graph,
        &env.kernel,
        &admm,
        NoiseModel::None,
        cfg.seed,
        Arc::new(NativeBackend),
    );
    let native_secs = sw.elapsed_secs();
    let native_sims: Vec<f64> = rep_native
        .alphas
        .iter()
        .zip(&env.xs)
        .map(|(a, x)| similarity(a, x, &central, &env.kernel))
        .collect();
    let drift = dkpca_sims
        .iter()
        .zip(&native_sims)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f64, f64::max);
    println!(
        "\ncross-check vs native backend: max similarity drift {drift:.2e} \
         (f32 artifacts vs f64 native), native wall {native_secs:.3}s"
    );
    let ok = Stats::from(&dkpca_sims).mean > Stats::from(&local_sims).mean && drift < 1e-2;
    println!("\nE2E {}", if ok { "OK" } else { "FAILED" });
    std::process::exit(if ok { 0 } else { 1 });
}
