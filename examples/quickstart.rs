//! Quickstart: run decentralized kernel PCA on a small synthetic
//! network, compare against the central solution, then export a
//! trained-model artifact and serve out-of-sample projections.
//!
//!     cargo run --release --example quickstart
//!
//! Five nodes observe samples from a shared two-blob mixture; the
//! network is a ring. After 30 ADMM iterations every node's local
//! direction w_j = phi(X_j) alpha_j aligns with the global kPCA
//! direction it could never compute alone. The trained model is then
//! frozen to a versioned artifact, reloaded, and a held-out batch is
//! projected through the serve API on both the exact and the RFF fast
//! path.

use dkpca::admm::{AdmmConfig, DkpcaSolver};
use dkpca::backend::NativeBackend;
use dkpca::central::{central_kpca, local_kpca, similarity};
use dkpca::data::synth::{blob_centers, sample_blobs, BlobSpec};
use dkpca::data::{NoiseModel, Rng};
use dkpca::kernels::Kernel;
use dkpca::model::DkpcaModel;
use dkpca::serve::{ProjectionEngine, ProjectionPath, ProjectionRequest};
use dkpca::topology::Graph;

fn main() {
    // 1. Data: five nodes, 30 samples each, one shared mixture.
    let spec = BlobSpec::default();
    let centers = blob_centers(&spec, 42);
    let mut rng = Rng::new(43);
    let xs: Vec<_> = (0..5)
        .map(|_| sample_blobs(&spec, &centers, 30, None, &mut rng).0)
        .collect();

    // 2. Topology: a ring — every node talks to two neighbors only.
    let graph = Graph::ring(5, 1);

    // 3. Kernel + ADMM configuration (paper §6.1 defaults).
    let kernel = Kernel::Rbf { gamma: 0.1 };
    let cfg = AdmmConfig { max_iters: 30, seed: 1, ..Default::default() };

    // 4. Run Alg. 1.
    let mut solver = DkpcaSolver::new(&xs, &graph, &kernel, &cfg, NoiseModel::None, 0);
    let result = solver.run(&NativeBackend);

    // 5. Evaluate against central kPCA (needs all data — only for the
    //    report, the algorithm never used it).
    let central = central_kpca(&xs, &kernel);
    println!("node |  local-only sim | DKPCA sim");
    println!("-----+-----------------+----------");
    for (j, x) in xs.iter().enumerate() {
        let local = similarity(&local_kpca(x, &kernel), x, &central, &kernel);
        let dkpca = similarity(&result.alphas[j], x, &central, &kernel);
        println!("   {j} |          {local:.4} |    {dkpca:.4}");
    }
    println!(
        "\ncommunication: {} floats total over {} iterations",
        result.comm_floats, result.iterations
    );

    // 6. Freeze the trained model into a versioned artifact and reload
    //    it — the train side ends here; everything below is inference.
    let artifact_path = std::env::temp_dir().join("dkpca_quickstart.dkpm");
    solver.to_model().save(&artifact_path).expect("save model artifact");
    let model = DkpcaModel::load(&artifact_path).expect("load model artifact");
    println!(
        "\nmodel artifact: {} nodes, {} support rows/node, {} bytes at {}",
        model.n_nodes(),
        model.nodes[0].support_len(),
        std::fs::metadata(&artifact_path).map(|m| m.len()).unwrap_or(0),
        artifact_path.display()
    );

    // 7. Serve a held-out batch through the projection engine: exact
    //    cross-Gram path vs the RFF fast path, per request.
    let held_out = sample_blobs(&spec, &centers, 8, None, &mut rng).0;
    let engine = ProjectionEngine::new(model, 2);
    let exact = engine
        .project(ProjectionRequest {
            node: 0,
            batch: held_out.clone(),
            path: ProjectionPath::Exact,
        })
        .expect("exact projection");
    let rff = engine
        .project(ProjectionRequest {
            node: 0,
            batch: held_out,
            path: ProjectionPath::Rff { dim: 2048, seed: 7 },
        })
        .expect("rff projection");
    println!("\nheld-out projections through node 0 (exact vs RFF-2048):");
    println!("point |     exact |       rff");
    println!("------+-----------+----------");
    for i in 0..exact.outputs.rows() {
        println!(
            "    {i} | {:>9.5} | {:>9.5}",
            exact.outputs[(i, 0)],
            rff.outputs[(i, 0)]
        );
    }
    let stats = engine.stats();
    println!(
        "\nserved {} requests / {} points ({} exact, {} rff)",
        stats.requests, stats.points, stats.exact_requests, stats.rff_requests
    );
    let _ = std::fs::remove_file(&artifact_path);
}
