//! Quickstart: run decentralized kernel PCA on a small synthetic
//! network and compare against the central solution.
//!
//!     cargo run --release --example quickstart
//!
//! Five nodes observe samples from a shared two-blob mixture; the
//! network is a ring. After 30 ADMM iterations every node's local
//! direction w_j = phi(X_j) alpha_j aligns with the global kPCA
//! direction it could never compute alone.

use dkpca::admm::{AdmmConfig, DkpcaSolver};
use dkpca::backend::NativeBackend;
use dkpca::central::{central_kpca, local_kpca, similarity};
use dkpca::data::synth::{blob_centers, sample_blobs, BlobSpec};
use dkpca::data::{NoiseModel, Rng};
use dkpca::kernels::Kernel;
use dkpca::topology::Graph;

fn main() {
    // 1. Data: five nodes, 30 samples each, one shared mixture.
    let spec = BlobSpec::default();
    let centers = blob_centers(&spec, 42);
    let mut rng = Rng::new(43);
    let xs: Vec<_> = (0..5)
        .map(|_| sample_blobs(&spec, &centers, 30, None, &mut rng).0)
        .collect();

    // 2. Topology: a ring — every node talks to two neighbors only.
    let graph = Graph::ring(5, 1);

    // 3. Kernel + ADMM configuration (paper §6.1 defaults).
    let kernel = Kernel::Rbf { gamma: 0.1 };
    let cfg = AdmmConfig { max_iters: 30, seed: 1, ..Default::default() };

    // 4. Run Alg. 1.
    let mut solver = DkpcaSolver::new(&xs, &graph, &kernel, &cfg, NoiseModel::None, 0);
    let result = solver.run(&NativeBackend);

    // 5. Evaluate against central kPCA (needs all data — only for the
    //    report, the algorithm never used it).
    let central = central_kpca(&xs, &kernel);
    println!("node |  local-only sim | DKPCA sim");
    println!("-----+-----------------+----------");
    for (j, x) in xs.iter().enumerate() {
        let local = similarity(&local_kpca(x, &kernel), x, &central, &kernel);
        let dkpca = similarity(&result.alphas[j], x, &central, &kernel);
        println!("   {j} |          {local:.4} |    {dkpca:.4}");
    }
    println!(
        "\ncommunication: {} floats total over {} iterations",
        result.comm_floats, result.iterations
    );
}
